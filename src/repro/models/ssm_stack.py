"""Stacks for the attention-free / hybrid families.

* ``ssm``    — rwkv6-3b: scan over RWKV-6 blocks; recurrent state replaces the
  KV cache (O(1) decode — this is why long_500k runs for this family).
* ``hybrid`` — zamba2-2.7b: Mamba-2 blocks with one weight-SHARED attention+FFN
  block applied every ``hybrid_attn_period`` blocks.  Segments are aligned to
  the period so the scan unit is (period x mamba blocks, shared attn).

Early-exit heads sit between segments, exactly as in ``transformer.py``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import rwkv6 as R6


def _round_to(x, m):
    return max(m, int(round(x / m)) * m)


def segment_lengths(cfg: ModelConfig):
    unit = cfg.hybrid_attn_period if cfg.family == "hybrid" else 1
    L_ = cfg.num_layers
    bounds = []
    for li in cfg.exit_layer_indices():
        b = min(max(unit, _round_to(li, unit)), L_ - unit)
        if b not in bounds:
            bounds.append(b)
    edges = [0] + sorted(bounds) + [L_]
    return [b - a for a, b in zip(edges[:-1], edges[1:])]


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    segs = segment_lengths(cfg)
    keys = jax.random.split(key, len(segs) + 4)
    init_layer = R6.init_layer if cfg.family == "ssm" else M2.init_layer
    params = {
        "embed": L.init_embed(keys[0], cfg, dtype),
        "segments": tuple(init_layer(keys[1 + i], cfg, dtype, stack=n)
                          for i, n in enumerate(segs)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.family == "hybrid":
        params["shared_attn"] = L.init_attn(keys[-2], cfg, dtype)
        params["shared_ffn"] = L.init_ffn(keys[-1], cfg, dtype)
    if cfg.num_exits:
        params["exit_norms"] = jnp.ones((len(segs) - 1, cfg.d_model), dtype)
    return params


def param_specs(cfg: ModelConfig):
    segs = segment_lengths(cfg)
    spec_layer = R6.spec_layer if cfg.family == "ssm" else M2.spec_layer
    specs = {
        "embed": L.spec_embed(),
        "segments": tuple(spec_layer(True) for _ in segs),
        "final_norm": P(None),
    }
    if cfg.family == "hybrid":
        from repro.config import MODEL_AXIS_SIZE
        specs["shared_attn"] = L.spec_attn(
            False, q_shard=cfg.padded_heads % MODEL_AXIS_SIZE == 0,
            kv_shard=cfg.num_kv_heads % MODEL_AXIS_SIZE == 0)
        specs["shared_ffn"] = L.spec_ffn(False)
    if cfg.num_exits:
        specs["exit_norms"] = P(None, None)
    return specs


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0), dtype))


# ----------------------------------------------------------------------------
# state ("cache") — the recurrent state that ships at a partition cut
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    segs = segment_lengths(cfg)
    cache = {"segments": []}
    for n in segs:
        if cfg.family == "ssm":
            seg = {
                "wkv": jnp.zeros((n, batch, cfg.num_heads, cfg.hd, cfg.hd), jnp.float32),
                "last_tm": jnp.zeros((n, batch, 1, cfg.d_model), jnp.float32),
                "last_cm": jnp.zeros((n, batch, 1, cfg.d_model), jnp.float32),
            }
        else:
            hm, ns = M2.n_heads(cfg), cfg.ssm_state
            seg = {
                "ssm": jnp.zeros((n, batch, hm, ns, M2.DH), jnp.float32),
                "conv": jnp.zeros((n, batch, M2.CONV_W - 1, M2.d_inner(cfg) + 2 * ns), jnp.float32),
            }
        cache["segments"].append(seg)
    cache["segments"] = tuple(cache["segments"])
    if cfg.family == "hybrid":
        napp = cfg.num_layers // cfg.hybrid_attn_period
        cache["shared_k"] = jnp.zeros((napp, batch, max_seq, cfg.num_kv_heads, cfg.hd), dtype)
        cache["shared_v"] = jnp.zeros((napp, batch, max_seq, cfg.num_kv_heads, cfg.hd), dtype)
    return cache


def cache_specs(cfg: ModelConfig, batch_axes, seq_axes="model"):
    segs = segment_lengths(cfg)
    out = {"segments": []}
    for _ in segs:
        if cfg.family == "ssm":
            # heads (40) don't divide the model axis; shard the key channels
            out["segments"].append({
                "wkv": P(None, batch_axes, None, "model", None),
                "last_tm": P(None, batch_axes, None, None),
                "last_cm": P(None, batch_axes, None, None),
            })
        else:
            # shard the SSM state dim N (not heads: hm=80 vs 16-way axis is
            # fine in production but smoke meshes need the same defensive
            # rule as rwkv)
            out["segments"].append({
                "ssm": P(None, batch_axes, None, "model", None),
                "conv": P(None, batch_axes, None, "model"),
            })
    out["segments"] = tuple(out["segments"])
    if cfg.family == "hybrid":
        out["shared_k"] = P(None, batch_axes, seq_axes, None, None)
        out["shared_v"] = P(None, batch_axes, seq_axes, None, None)
    return out


# ----------------------------------------------------------------------------
# segment runners
# ----------------------------------------------------------------------------

def _run_rwkv_segment(cfg, segp, x, seg_state, *, mode="auto", use_kernel=False,
                      remat=False, chunk=16):
    def body(carry, xs):
        x = carry
        lp, st = xs
        x, wkv, lasts = R6.block(lp, cfg, x, st["wkv"],
                                 (st["last_tm"].astype(x.dtype), st["last_cm"].astype(x.dtype)),
                                 mode=mode, use_kernel=use_kernel, chunk=chunk)
        return x, {"wkv": wkv, "last_tm": lasts[0].astype(jnp.float32),
                   "last_cm": lasts[1].astype(jnp.float32)}

    fn = jax.checkpoint(body) if remat else body
    x, new_state = jax.lax.scan(fn, x, (segp, seg_state))
    return x, new_state


def _run_mamba_segment(cfg, params, segp, x, seg_state, shared_cache, app_offset,
                       positions, *, mode="auto", use_kernel=False, remat=False,
                       cache_pos=None, prefill_mode=False, attn_impl="auto",
                       chunk=16):
    """Segment of `n` mamba blocks; shared attn block after every
    ``hybrid_attn_period`` blocks.  ``shared_cache``: (k,v) slices for this
    segment's applications, [napp_seg, B, S, KV, hd] or None (training)."""
    period = cfg.hybrid_attn_period
    n = jax.tree_util.tree_leaves(segp)[0].shape[0]
    napp = n // period
    # reshape stacked params/state to [napp, period, ...]
    seg_sup = jax.tree.map(lambda a: a.reshape((napp, period) + a.shape[1:]), segp)
    st_sup = jax.tree.map(lambda a: a.reshape((napp, period) + a.shape[1:]), seg_state)

    def super_body(carry, xs):
        x = carry
        if shared_cache is None:
            lp, st = xs
            kc = vc = None
        else:
            lp, st, kc, vc = xs

        def mamba_body(c, xs2):
            x = c
            lp2, st2 = xs2
            o, ssm, conv = M2.block(lp2, cfg, x, st2["ssm"],
                                    st2["conv"].astype(x.dtype), mode=mode,
                                    use_kernel=use_kernel, chunk=chunk)
            return x + o, {"ssm": ssm, "conv": conv.astype(jnp.float32)}

        x, new_st = jax.lax.scan(mamba_body, x, (lp, st))
        # weight-shared attention + ffn block
        a, nc = L.attention(params["shared_attn"], cfg, x, positions,
                            kv_cache=None if kc is None else (kc, vc),
                            cache_pos=cache_pos, impl=attn_impl,
                            prefill_mode=prefill_mode)
        x = x + a
        x = x + L.ffn(params["shared_ffn"], cfg, x)
        return x, (new_st, (None if nc is None else nc))

    fn = jax.checkpoint(super_body) if remat else super_body
    xs = (seg_sup, st_sup) if shared_cache is None else (seg_sup, st_sup, shared_cache[0], shared_cache[1])
    x, (new_state, new_kv) = jax.lax.scan(fn, x, xs)
    new_state = jax.tree.map(lambda a: a.reshape((n,) + a.shape[2:]), new_state)
    return x, new_state, new_kv


# ----------------------------------------------------------------------------
# public API (mirrors transformer.py)
# ----------------------------------------------------------------------------

def _stack_forward(cfg, params, x, cache, *, mode, exit_point=None,
                   collect_exits=True, use_kernel=False, remat=False,
                   cache_pos=None, prefill_mode=False, attn_impl="auto",
                   chunk=16):
    B, S, _ = x.shape
    base = 0 if cache_pos is None else cache_pos
    positions = jnp.broadcast_to(base + jnp.arange(S)[None], (B, S))
    segs = segment_lengths(cfg)
    n_seg = len(segs) if exit_point is None else exit_point + 1
    new_cache = dict(cache)
    new_cache["segments"] = list(cache["segments"])
    cur_k = cache.get("shared_k")
    cur_v = cache.get("shared_v")
    outs = []
    app_off = 0
    for si in range(n_seg):
        segp = params["segments"][si]
        if cfg.family == "ssm":
            x, nst = _run_rwkv_segment(cfg, segp, x, cache["segments"][si],
                                       mode=mode, use_kernel=use_kernel,
                                       remat=remat, chunk=chunk)
        else:
            napp = segs[si] // cfg.hybrid_attn_period
            shared = None
            if cur_k is not None:
                shared = (jax.lax.dynamic_slice_in_dim(cur_k, app_off, napp, 0),
                          jax.lax.dynamic_slice_in_dim(cur_v, app_off, napp, 0))
            x, nst, nkv = _run_mamba_segment(
                cfg, params, segp, x, cache["segments"][si], shared, app_off,
                positions, mode=mode, use_kernel=use_kernel, remat=remat,
                cache_pos=cache_pos, prefill_mode=prefill_mode,
                attn_impl=attn_impl, chunk=chunk)
            if nkv is not None and cur_k is not None:
                cur_k = jax.lax.dynamic_update_slice_in_dim(cur_k, nkv[0].astype(cur_k.dtype), app_off, 0)
                cur_v = jax.lax.dynamic_update_slice_in_dim(cur_v, nkv[1].astype(cur_v.dtype), app_off, 0)
            app_off += napp
        new_cache["segments"][si] = nst
        is_last = si == n_seg - 1
        if not is_last and cfg.num_exits and collect_exits:
            outs.append((si, L.rms_norm(x, params["exit_norms"][si], cfg.norm_eps)))
        if is_last:
            norm = params["final_norm"] if exit_point in (None, len(segs) - 1) \
                else params["exit_norms"][si]
            outs.append((si, L.rms_norm(x, norm, cfg.norm_eps)))
    if cur_k is not None:
        new_cache["shared_k"], new_cache["shared_v"] = cur_k, cur_v
    new_cache["segments"] = tuple(new_cache["segments"])
    return outs, new_cache


def forward(cfg: ModelConfig, params, tokens, *, exit_point=None,
            collect_exits=True, use_kernel=False, remat=False, mode="auto",
            attn_impl="auto", scan_chunk=16, **_):
    x = L.embed(params["embed"], tokens)
    cache = init_cache(cfg, tokens.shape[0], max_seq=tokens.shape[1],
                       dtype=x.dtype)
    outs, _cache = _stack_forward(cfg, params, x, cache, mode=mode,
                                  exit_point=exit_point, collect_exits=collect_exits,
                                  use_kernel=use_kernel, remat=remat,
                                  prefill_mode=True,
                                  cache_pos=0 if cfg.family == "hybrid" else None,
                                  attn_impl=attn_impl, chunk=scan_chunk)
    return outs, 0.0


def prefill(cfg: ModelConfig, params, tokens, cache, *, use_kernel=False,
            mode="auto", attn_impl="auto", **_):
    x = L.embed(params["embed"], tokens)
    # hybrid prefill writes shared-attn KV at [0, S)
    outs, new_cache = _stack_forward(cfg, params, x, cache, mode=mode,
                                     collect_exits=False, use_kernel=use_kernel,
                                     prefill_mode=True,
                                     cache_pos=0 if cfg.family == "hybrid" else None,
                                     attn_impl=attn_impl)
    _, h = outs[-1]
    return h[:, -1:, :], new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos, *,
                exit_point=None, use_kernel=False, **_):
    x = L.embed(params["embed"], tokens)
    outs, new_cache = _stack_forward(cfg, params, x, cache, mode="sequential",
                                     exit_point=exit_point, collect_exits=False,
                                     use_kernel=use_kernel, cache_pos=pos)
    _, h = outs[-1]
    return h, new_cache, []
