"""Mamba-2 (SSD) block and the zamba2 hybrid pattern (arXiv:2411.15242).

SSD recurrence per head (headdim ``dh=64``, state N = cfg.ssm_state):
    a_t = exp(dt_t * A_h)    (A_h < 0, scalar per head)
    S_t = a_t S_{t-1} + (dt_t x_t) B_t^T ;   y_t = S_t C_t + D_h x_t
which maps onto the shared diagonal-decay scan with q=C, k=B,
v=dt*x, per-head scalar decay broadcast over state channels.

zamba2: ``num_layers`` mamba blocks; one weight-*shared* attention+FFN block
applied every ``hybrid_attn_period`` blocks (single weight copy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.linear_scan import linear_scan

DH = 64      # mamba2 head dim
CONV_W = 4   # causal depthwise conv width


def d_inner(cfg: ModelConfig) -> int:
    return 2 * cfg.d_model


def n_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // DH


def init_layer(key, cfg: ModelConfig, dtype, stack: int = 0):
    d = cfg.d_model
    di, n, hm = d_inner(cfg), cfg.ssm_state, n_heads(cfg)
    pre = (stack,) if stack else ()
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.ones(pre + (d,), dtype),
        # fused in_proj -> [z, x, B, C, dt]
        "w_in": dense_init(ks[0], pre + (d, 2 * di + 2 * n + hm), dtype, d),
        "conv": dense_init(ks[1], pre + (CONV_W, di + 2 * n), dtype, CONV_W),
        "A_log": jnp.zeros(pre + (hm,), jnp.float32),      # A = -exp(A_log)
        "D": jnp.ones(pre + (hm,), jnp.float32),
        "dt_bias": jnp.zeros(pre + (hm,), jnp.float32),
        "w_out": dense_init(ks[2], pre + (di, d), dtype, di),
        "gn": jnp.ones(pre + (di,), dtype),
    }


def spec_layer(stack: bool = False):
    pre = (None,) if stack else ()
    return {
        "ln": P(*pre, None),
        # fused in_proj width (2*di + 2n + hm) is not 16-divisible: shard the
        # d_model (input) dim instead
        "w_in": P(*pre, "data", None),
        "conv": P(*pre, None, "model"),
        "A_log": P(*pre, None), "D": P(*pre, None), "dt_bias": P(*pre, None),
        "w_out": P(*pre, "model", "data"),
        "gn": P(*pre, "model"),
    }


def _split_in(cfg, h):
    di, n, hm = d_inner(cfg), cfg.ssm_state, n_heads(cfg)
    z, x, B_, C_, dt = jnp.split(h, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, x, B_, C_, dt


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv, width CONV_W. x: [B,S,C]; w: [CONV_W, C].
    conv_state: [B, CONV_W-1, C] trailing context (decode)."""
    if conv_state is not None:
        x = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        new_state = x[:, -(CONV_W - 1):, :]
    else:
        x = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
        new_state = x[:, -(CONV_W - 1):, :]
    out = sum(x[:, i : x.shape[1] - (CONV_W - 1 - i), :] * w[i] for i in range(CONV_W))
    return out, new_state


def block(p, cfg: ModelConfig, x, state, conv_state=None, *, mode="auto",
          use_kernel=False, chunk=16):
    """x: [B,S,D]; state: [B,Hm,N,DH] f32 (k-dim=N, v-dim=DH).
    Returns (out, new_state, new_conv_state)."""
    B, S, D = x.shape
    di, n, hm = d_inner(cfg), cfg.ssm_state, n_heads(cfg)
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xi, Bc, Cc, dt = _split_in(cfg, xn @ p["w_in"])
    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xi, Bc, Cc = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # [B,S,Hm]
    A = -jnp.exp(p["A_log"])                                           # [Hm]
    log_w = (dt * A)[..., None]                                        # [B,S,Hm,1]
    log_w = jnp.broadcast_to(log_w, (B, S, hm, n))                     # per-channel
    xh = xi.reshape(B, S, hm, DH) * dt[..., None].astype(xi.dtype)     # v = dt*x
    k = jnp.broadcast_to(Bc[:, :, None, :], (B, S, hm, n)).astype(xi.dtype)
    q = jnp.broadcast_to(Cc[:, :, None, :], (B, S, hm, n)).astype(xi.dtype)
    y, new_state = linear_scan(q, k, xh, log_w, state, u=None, mode=mode,
                               use_kernel=use_kernel, chunk=chunk)     # [B,S,Hm,DH]
    y = y + xi.reshape(B, S, hm, DH) * p["D"][:, None].astype(xi.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y, p["gn"], cfg.norm_eps) * jax.nn.silu(z)
    return (y @ p["w_out"]), new_state, new_conv


def init_state(cfg: ModelConfig, batch: int):
    hm, n = n_heads(cfg), cfg.ssm_state
    return {
        "ssm": jnp.zeros((cfg.num_layers, batch, hm, n, DH), jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, batch, CONV_W - 1, d_inner(cfg) + 2 * n), jnp.float32),
    }


def state_specs(batch_axes):
    return {
        "ssm": P(None, batch_axes, None, "model", None),
        "conv": P(None, batch_axes, None, "model"),
    }
