"""Branchy AlexNet — the paper's prototype (Fig. 4), CIFAR-10 scale, pure JAX.

The model is expressed as an explicit *layer graph*: a main branch of 22
layers plus four side branches, so that branch ``i`` (exit point ``i``) has
N_i layers = 12, 16, 19, 20, 22 — matching Sec. V-A.  Layer kinds are exactly
the paper's Table-I types (conv / relu / lrn / pooling / dropout / fc), and
every layer exposes the Table-I regression features plus its output size —
the inputs of the Edgent partitioner.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class BranchyAlexNetConfig:
    name: str = "branchy-alexnet"
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3


@dataclass(frozen=True)
class LayerSpec:
    name: str
    kind: str                    # conv | relu | lrn | pool | dropout | fc
    out_ch: int = 0              # conv filters / fc out features
    filt: int = 0                # conv/pool window
    stride: int = 1
    drop_rate: float = 0.5


def _main_branch(cfg: BranchyAlexNetConfig) -> List[LayerSpec]:
    return [
        LayerSpec("conv1", "conv", out_ch=32, filt=5, stride=1),
        LayerSpec("relu1", "relu"),
        LayerSpec("lrn1", "lrn"),
        LayerSpec("pool1", "pool", filt=3, stride=2),
        LayerSpec("conv2", "conv", out_ch=64, filt=5, stride=1),
        LayerSpec("relu2", "relu"),
        LayerSpec("lrn2", "lrn"),
        LayerSpec("pool2", "pool", filt=3, stride=2),
        LayerSpec("conv3", "conv", out_ch=96, filt=3, stride=1),
        LayerSpec("relu3", "relu"),
        LayerSpec("conv4", "conv", out_ch=96, filt=3, stride=1),
        LayerSpec("relu4", "relu"),
        LayerSpec("conv5", "conv", out_ch=64, filt=3, stride=1),
        LayerSpec("relu5", "relu"),
        LayerSpec("pool5", "pool", filt=3, stride=2),
        LayerSpec("fc1", "fc", out_ch=256),
        LayerSpec("relu6", "relu"),
        LayerSpec("drop1", "dropout"),
        LayerSpec("fc2", "fc", out_ch=128),
        LayerSpec("relu7", "relu"),
        LayerSpec("drop2", "dropout"),
        LayerSpec("fc3", "fc", out_ch=10),
    ]


def _side_branches(cfg) -> List[Tuple[int, List[LayerSpec]]]:
    """(prefix length into main, branch layers).  Branch lengths:
    8+4=12, 10+6=16, 15+4=19, 18+2=20 — plus the 22-layer main = exit 5."""
    c = cfg.num_classes
    return [
        (8, [LayerSpec("b1_conv", "conv", out_ch=32, filt=3),
             LayerSpec("b1_relu", "relu"),
             LayerSpec("b1_pool", "pool", filt=3, stride=2),
             LayerSpec("b1_fc", "fc", out_ch=c)]),
        (10, [LayerSpec("b2_conv", "conv", out_ch=32, filt=3),
              LayerSpec("b2_relu", "relu"),
              LayerSpec("b2_pool", "pool", filt=3, stride=2),
              LayerSpec("b2_fc1", "fc", out_ch=64),
              LayerSpec("b2_relu2", "relu"),
              LayerSpec("b2_fc2", "fc", out_ch=c)]),
        (15, [LayerSpec("b3_fc1", "fc", out_ch=128),
              LayerSpec("b3_relu", "relu"),
              LayerSpec("b3_drop", "dropout"),
              LayerSpec("b3_fc2", "fc", out_ch=c)]),
        (18, [LayerSpec("b4_fc1", "fc", out_ch=32),
              LayerSpec("b4_fc2", "fc", out_ch=c)]),
    ]


# ----------------------------------------------------------------------------
# single-layer semantics
# ----------------------------------------------------------------------------

def layer_out_shape(spec: LayerSpec, in_shape):
    """in_shape excl. batch: (H, W, C) or (F,)."""
    if spec.kind == "conv":
        h, w, _ = in_shape
        return (h // spec.stride, w // spec.stride, spec.out_ch)
    if spec.kind == "pool":
        h, w, c = in_shape
        return (math.ceil(h / spec.stride), math.ceil(w / spec.stride), c)
    if spec.kind == "fc":
        return (spec.out_ch,)
    return tuple(in_shape)


def layer_features(spec: LayerSpec, in_shape) -> Dict[str, float]:
    """Table-I independent variables for the latency regression models."""
    in_size = float(np.prod(in_shape))
    out_size = float(np.prod(layer_out_shape(spec, in_shape)))
    if spec.kind == "conv":
        return {"in_maps": float(in_shape[-1]),
                "comp": (spec.filt / spec.stride) ** 2 * spec.out_ch,
                "in_size": in_size}
    if spec.kind in ("relu", "lrn", "dropout"):
        return {"in_size": in_size}
    if spec.kind == "pool":
        return {"in_size": in_size, "out_size": out_size}
    if spec.kind == "fc":
        return {"in_size": in_size, "out_size": out_size}
    raise ValueError(spec.kind)


def init_layer(spec: LayerSpec, key, in_shape, dtype=jnp.float32):
    if spec.kind == "conv":
        cin = in_shape[-1]
        w = jax.random.normal(key, (spec.filt, spec.filt, cin, spec.out_ch), dtype)
        w = w / math.sqrt(spec.filt * spec.filt * cin)
        return {"w": w, "b": jnp.zeros((spec.out_ch,), dtype)}
    if spec.kind == "fc":
        fin = int(np.prod(in_shape))
        w = jax.random.normal(key, (fin, spec.out_ch), dtype) / math.sqrt(fin)
        return {"w": w, "b": jnp.zeros((spec.out_ch,), dtype)}
    return {}


def apply_layer(spec: LayerSpec, p, x, *, train=False, rng=None):
    """x: [B, H, W, C] or [B, F]."""
    if spec.kind == "conv":
        out = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(spec.stride, spec.stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return out + p["b"]
    if spec.kind == "relu":
        return jax.nn.relu(x)
    if spec.kind == "lrn":
        # local response normalization across channels, window 5
        sq = jnp.square(x)
        win = 5
        pad = win // 2
        summed = sum(
            jnp.pad(sq, [(0, 0)] * (x.ndim - 1) + [(pad, pad)])[..., i : i + x.shape[-1]]
            for i in range(win))
        return x / jnp.power(2.0 + 1e-4 * summed, 0.75)
    if spec.kind == "pool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            (1, spec.filt, spec.filt, 1), (1, spec.stride, spec.stride, 1), "SAME")
    if spec.kind == "dropout":
        if not train:
            return x
        keep = 1.0 - spec.drop_rate
        mask = jax.random.bernoulli(rng, keep, x.shape)
        return jnp.where(mask, x / keep, 0.0)
    if spec.kind == "fc":
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        return x @ p["w"] + p["b"]
    raise ValueError(spec.kind)


# ----------------------------------------------------------------------------
# model
# ----------------------------------------------------------------------------

class BranchyAlexNet:
    """Five-exit branchy AlexNet with an explicit per-branch layer list."""

    def __init__(self, cfg: BranchyAlexNetConfig):
        self.cfg = cfg
        self.main = _main_branch(cfg)
        self.sides = _side_branches(cfg)
        self.num_exits = len(self.sides) + 1  # 5

    # -- structure ---------------------------------------------------------
    def branch_layers(self, exit_idx: int) -> List[LayerSpec]:
        """Full layer list of branch `exit_idx` (1-based, paper numbering:
        exit 1 shortest ... exit 5 = main)."""
        if exit_idx == self.num_exits:
            return list(self.main)
        prefix, side = self.sides[exit_idx - 1]
        return list(self.main[:prefix]) + list(side)

    def branch_shapes(self, exit_idx: int):
        """Per-layer (in_shape, out_shape) excl. batch for branch."""
        shape = (self.cfg.image_size, self.cfg.image_size, self.cfg.channels)
        out = []
        for spec in self.branch_layers(exit_idx):
            o = layer_out_shape(spec, shape)
            out.append((shape, o))
            shape = o
        return out

    # -- params ------------------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        params = {}
        shape = (self.cfg.image_size, self.cfg.image_size, self.cfg.channels)
        shapes = {}
        for spec in self.main:
            key, k = jax.random.split(key)
            params[spec.name] = init_layer(spec, k, shape, dtype)
            shapes[spec.name] = shape
            shape = layer_out_shape(spec, shape)
        for prefix, side in self.sides:
            shape = (self.cfg.image_size, self.cfg.image_size, self.cfg.channels)
            for spec in self.main[:prefix]:
                shape = layer_out_shape(spec, shape)
            for spec in side:
                key, k = jax.random.split(key)
                params[spec.name] = init_layer(spec, k, shape, dtype)
                shape = layer_out_shape(spec, shape)
        return params

    # -- execution ---------------------------------------------------------
    def run_layers(self, params, x, layer_list, lo=0, hi=None, *, train=False,
                   rng=None):
        hi = len(layer_list) if hi is None else hi
        for spec in layer_list[lo:hi]:
            if rng is not None:
                rng, sub = jax.random.split(rng)
            else:
                sub = None
            x = apply_layer(spec, params.get(spec.name, {}), x, train=train, rng=sub)
        return x

    def forward_exit(self, params, x, exit_idx: int, *, train=False, rng=None):
        return self.run_layers(params, x, self.branch_layers(exit_idx),
                               train=train, rng=rng)

    def forward_all(self, params, x, *, train=False, rng=None):
        """Logits at every exit (BranchyNet joint training)."""
        return [self.forward_exit(params, x, i + 1, train=train,
                                  rng=None if rng is None else jax.random.fold_in(rng, i))
                for i in range(self.num_exits)]

    def loss(self, params, batch, rng, weights=None):
        """Joint weighted CE over all exits."""
        x, y = batch
        logits = self.forward_all(params, x, train=True, rng=rng)
        w = weights or [1.0] * self.num_exits
        losses = []
        for lg in logits:
            lp = jax.nn.log_softmax(lg)
            losses.append(-jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=1)))
        return sum(wi * li for wi, li in zip(w, losses)) / sum(w)

    def accuracy(self, params, x, y, exit_idx: int):
        logits = self.forward_exit(params, x, exit_idx)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
