from repro.models.api import Model, softmax_xent  # noqa: F401
